"""Step builders: the jittable (train / prefill / decode) step functions with
their abstract inputs, used by both the dry-run and the CPU-scale drivers."""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import HierarchyConfig, ModelConfig, ShapeConfig, TrainConfig
from repro.core.phsfl import abstract_params, build_optimizer, make_phsfl_round
from repro.launch import input_specs as ispec
from repro.launch.mesh import num_clients
from repro.models.registry import Model, build_model
from repro.models import transformer as tf_mod
from repro.sharding.rules import named_sharding, params_specs
from repro.utils.tree import map_with_path


@dataclass
class StepBundle:
    """A step function plus abstract (sharded) example arguments."""
    fn: Callable
    args: tuple
    kind: str
    meta: dict


# ----------------------------------------------------------- train ---------
def build_train_step(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                     tcfg: TrainConfig | None = None,
                     hcfg: HierarchyConfig | None = None) -> StepBundle:
    """The paper-faithful PHSFL edge round (with global sync on multi-pod)."""
    tcfg = tcfg or TrainConfig()
    hcfg = hcfg or HierarchyConfig()
    model = build_model(cfg)
    C = num_clients(mesh)
    multi = "pod" in mesh.axis_names

    round_ = make_phsfl_round(model, hcfg, tcfg, mesh, global_sync=multi)
    opt, _ = build_optimizer(model, tcfg)

    pshapes = abstract_params(model, stacked_clients=C)
    pshard = named_sharding(mesh, round_.params_spec)
    params = jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        pshapes, pshard)

    sshapes = jax.eval_shape(
        lambda: opt.init(jax.tree.map(
            lambda s: jnp.zeros(s.shape[1:], s.dtype), pshapes)))
    lead = ispec._dab(mesh)

    def stack_state(s):
        return jax.ShapeDtypeStruct((C,) + s.shape, s.dtype,
                                    sharding=NamedSharding(mesh, P(lead)))

    opt_state = jax.tree.map(stack_state, sshapes)
    batch = ispec.train_batch_specs(cfg, shape, mesh, tcfg)
    au, ab = ispec.train_weight_specs(mesh)
    return StepBundle(fn=round_.fn, args=(params, opt_state, batch, au, ab),
                      kind="train",
                      meta={"clients": C, "local_steps": tcfg.local_steps_in_step,
                            "global_sync": multi, "mode": "paper_faithful"})


def build_shared_server_train_step(cfg: ModelConfig, shape: ShapeConfig,
                                   mesh: Mesh,
                                   tcfg: TrainConfig | None = None,
                                   hcfg: HierarchyConfig | None = None
                                   ) -> StepBundle:
    """Beyond-paper shared-server (SFL-V2) step for the same shapes."""
    from repro.core.phsfl import make_shared_server_step
    from repro.core.split import part_masks, split_spec_for

    tcfg = tcfg or TrainConfig(shared_server=True)
    hcfg = hcfg or HierarchyConfig()
    model = build_model(cfg)
    C = num_clients(mesh)
    step = make_shared_server_step(model, hcfg, tcfg, mesh, C)

    shapes = abstract_params(model)
    masks = part_masks(shapes, split_spec_for(cfg))
    pspec = params_specs(shapes, model.axes(), mesh, mode="fsdp_tp")
    lead = ispec._dab(mesh)

    def stacked(mask_c, s, sp):
        if mask_c:  # client block: per-client, replicate inner dims
            return jax.ShapeDtypeStruct(
                (C,) + s.shape, s.dtype,
                sharding=NamedSharding(mesh, P(lead)))
        return jax.ShapeDtypeStruct(s.shape, s.dtype,
                                    sharding=NamedSharding(mesh, sp))

    params = jax.tree.map(stacked, masks["client"], shapes, pspec,
                          is_leaf=lambda x: isinstance(x, bool))
    opt, _ = build_optimizer(model, tcfg)
    sshapes = jax.eval_shape(lambda: opt.init(params))
    opt_state = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), sshapes)

    # batch: (C, micro, seq) — one local step per call in this mode
    micro = shape.global_batch // C
    tok = ispec._sds((C, micro, shape.seq_len), jnp.int32, mesh, P(lead))
    batch = {"tokens": tok, "labels": tok}
    batch.update(ispec._extras_specs(cfg, (C, micro), shape.seq_len, mesh, lead))
    return StepBundle(fn=step.fn, args=(params, opt_state, batch),
                      kind="train",
                      meta={"clients": C, "mode": "shared_server"})


# ------------------------------------------------------ prefill / decode ---
def build_prefill_step(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh, *,
                       param_mode: str = "fsdp_tp") -> StepBundle:
    model = build_model(cfg)
    shapes = abstract_params(model)
    pspec = params_specs(shapes, model.axes(), mesh, mode=param_mode)
    params = jax.tree.map(
        lambda s, sp: jax.ShapeDtypeStruct(
            s.shape, s.dtype, sharding=NamedSharding(mesh, sp)),
        shapes, pspec, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    batch = ispec.prefill_batch_specs(cfg, shape, mesh)

    def prefill_fn(params, batch):
        hidden, _ = model.apply(params, batch, remat=False)
        # last-position logits (what serving returns after prefill)
        return tf_mod.logits_from_hidden(params, cfg, hidden[:, -1:, :])

    return StepBundle(fn=prefill_fn, args=(params, batch), kind="prefill",
                      meta={"mode": "serving"})


def build_decode_step(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh, *,
                      param_mode: str = "fsdp_tp") -> StepBundle:
    model = build_model(cfg)
    shapes = abstract_params(model)
    pspec = params_specs(shapes, model.axes(), mesh, mode=param_mode)
    params = jax.tree.map(
        lambda s, sp: jax.ShapeDtypeStruct(
            s.shape, s.dtype, sharding=NamedSharding(mesh, sp)),
        shapes, pspec, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    tok, extras = ispec.decode_token_specs(cfg, shape, mesh)
    cache = ispec.cache_specs(model, shape, mesh)
    index = jax.ShapeDtypeStruct((), jnp.int32)

    if extras:
        def decode_fn(params, token, cache, index, positions3):
            return model.decode_step(params, token, cache, index,
                                     positions3=positions3)

        args = (params, tok, cache, index, extras["positions3"])
    else:
        def decode_fn(params, token, cache, index):
            return model.decode_step(params, token, cache, index)

        args = (params, tok, cache, index)
    return StepBundle(fn=decode_fn, args=args, kind="decode",
                      meta={"mode": "serving", "cache_len": shape.seq_len,
                            "param_mode": param_mode})


def build_step(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh, *,
               train_mode: str = "paper_faithful",
               serve_param_mode: str = "fsdp_tp",
               tcfg: TrainConfig | None = None) -> StepBundle:
    if shape.kind == "train":
        if train_mode == "shared_server":
            return build_shared_server_train_step(cfg, shape, mesh, tcfg)
        return build_train_step(cfg, shape, mesh, tcfg)
    if shape.kind == "prefill":
        return build_prefill_step(cfg, shape, mesh,
                                  param_mode=serve_param_mode)
    if shape.kind == "decode":
        return build_decode_step(cfg, shape, mesh,
                                 param_mode=serve_param_mode)
    raise ValueError(shape.kind)
