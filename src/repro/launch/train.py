"""End-to-end PHSFL training driver (deliverable b's e2e example backend).

Runs REAL training on this machine (CPU, one device — mesh (1,1) or the
fake multi-device mesh if XLA_FLAGS is set by the caller) at a reduced scale
of any assigned architecture, through the same make_phsfl_round code path
the dry-run lowers for the production mesh:

    PYTHONPATH=src python -m repro.launch.train --arch xlstm-350m \
        --rounds 20 --clients 4 --seq 128

After global training it fine-tunes per-client heads (Eq. 18) and reports
global vs personalized loss per client.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.checkpoint import latest_step, load_checkpoint, save_checkpoint
from repro.configs.base import (FaultConfig, HierarchyConfig, TrainConfig,
                                WirelessConfig)
from repro.configs.registry import get_arch
from repro.core import (build_optimizer, init_stacked_params,
                        make_host_round, make_phsfl_round,
                        personalize_head_bank, personalized_eval)
from repro.core.comm import comm_for_lm, comm_table_for_lm
from repro.core.hierarchy import es_assignment
from repro.data.synthetic import synthetic_token_batch
from repro.launch.mesh import set_mesh
from repro.models import build_model
from repro.telemetry import MetricLogger, Telemetry
from repro.wireless import make_scheduler


def _client_round_batch(cfg, C, k, micro, seq, seed):
    """Stacked per-client batches; each client gets a DIFFERENT token
    distribution (client id shifts the vocab) => non-IID federated data."""
    toks, labs = [], []
    for c in range(C):
        nb = synthetic_token_batch(seed * 1000 + c, k * micro, seq,
                                   max(cfg.vocab_size // 2, 2))
        shift = (c * cfg.vocab_size) // (2 * max(C, 1))
        toks.append((nb["tokens"] + shift) % cfg.vocab_size)
        labs.append((nb["labels"] + shift) % cfg.vocab_size)
    batch = {
        "tokens": jnp.asarray(np.stack(toks)).reshape(C, k, micro, seq),
        "labels": jnp.asarray(np.stack(labs)).reshape(C, k, micro, seq),
    }
    if cfg.encdec is not None:
        batch["source_embeds"] = 0.02 * jnp.ones(
            (C, k, micro, cfg.encdec.max_source_len, cfg.d_model),
            jnp.float32)
    if cfg.vlm is not None:
        batch["patch_embeds"] = 0.02 * jnp.ones(
            (C, k, micro, cfg.vlm.num_patch_tokens, cfg.d_model), jnp.float32)
        batch["positions3"] = jnp.tile(
            jnp.arange(seq, dtype=jnp.int32)[None, None, None, :, None],
            (C, k, micro, 1, 3))
    return batch


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-350m")
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--local-steps", type=int, default=2)
    ap.add_argument("--micro", type=int, default=2)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--hsfl", action="store_true",
                    help="baseline: do NOT freeze the head")
    ap.add_argument("--finetune-steps", type=int, default=5)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0,
                    help="write a FULL training-state checkpoint (params, "
                         "optimizer, round cursor, scheduler RNG/energy "
                         "state) into {ckpt-dir}/state every N rounds; a "
                         "killed run then resumes bit-identically (0 = "
                         "final-params checkpoint only)")
    ap.add_argument("--resume", action="store_true",
                    help="resume from the latest state checkpoint in "
                         "{ckpt-dir}/state (fresh start if none exists)")
    ap.add_argument("--abort-after", type=int, default=None,
                    help="kill the run right after this round's state "
                         "checkpoint (crash simulation for the resume "
                         "smoke test)")
    ap.add_argument("--seed", type=int, default=0)
    # ---- population-scale cohorts (repro.wireless.population) ----
    ap.add_argument("--population", type=int, default=0,
                    help="register N clients in a persistent population and "
                         "sample a cohort per round; the scheduler then "
                         "prices ALL N channels/budgets while only the "
                         "cohort trains (0 = classic fixed-client mode). "
                         "Requires a non-ideal --channel")
    ap.add_argument("--cohort-size", type=int, default=None,
                    help="clients trained per round in population mode "
                         "(default: --clients); becomes the slot count of "
                         "the training mesh")
    ap.add_argument("--sampling", default="uniform",
                    choices=["uniform", "rate", "pareto"],
                    help="cohort sampling rule: uniform, biased toward "
                         "good channels (rate), or a Pareto-style "
                         "participation cap (least-sampled first)")
    # ---- wireless scenario (repro.wireless) ----
    ap.add_argument("--channel", default="ideal",
                    choices=["ideal", "static", "rayleigh"],
                    help="per-client channel model (ideal = pre-wireless)")
    ap.add_argument("--deadline", type=float, default=float("inf"),
                    help="edge-round deadline in seconds; stragglers drop")
    ap.add_argument("--mean-rate-mbps", type=float, default=100.0,
                    help="mean per-client uplink rate")
    ap.add_argument("--energy-budget", type=float, default=float("inf"),
                    help="lifetime per-client uplink energy budget (J)")
    ap.add_argument("--es-uplink-mbps", type=float, default=float("inf"),
                    help="shared ES uplink capacity, split among that "
                         "round's scheduled clients (inf = private uplinks)")
    ap.add_argument("--cut-policy", default="fixed",
                    choices=["fixed", "greedy", "deadline"],
                    help="per-round cut-layer selection policy "
                         "(repro.wireless.cutter)")
    ap.add_argument("--cut-candidates", type=int, nargs="+", default=None,
                    help="candidate client depths (n_client_layers), "
                         "shallow to deep; default: the model's depth only")
    # ---- device (compute) model (repro.wireless.device) ----
    ap.add_argument("--compute-gflops", type=float, default=float("inf"),
                    help="per-client compute rate in GFLOP/s; client-block "
                         "FLOPs then cost round time and energy (inf = "
                         "free compute, the bits-only accounting)")
    ap.add_argument("--compute-heterogeneity", type=float, default=0.0,
                    help="lognormal sigma of a fixed per-client compute "
                         "scale (0 = identical devices)")
    ap.add_argument("--compute-power-w", type=float, default=0.0,
                    help="power drawn while computing; joins tx energy in "
                         "the per-client budget gate")
    ap.add_argument("--codec-cycles", type=float, default=0.0,
                    help="FLOPs per element crossing a lossy codec "
                         "(encode/decode compute; 0 = codecs compute-free)")
    # ---- fault injection (repro.wireless.faults) ----
    ap.add_argument("--erasure-prob", type=float, default=0.0,
                    help="per-attempt payload erasure probability; erased "
                         "transmissions retransmit (HARQ) as real timeline "
                         "segments, priced in the deadline/energy/bits "
                         "accounting")
    ap.add_argument("--harq-retries", type=int, default=2,
                    help="max retransmissions per payload before it FAILS")
    ap.add_argument("--harq-backoff", type=float, default=0.0,
                    help="radio-idle seconds before each retransmission")
    ap.add_argument("--crash-hazard", type=float, default=0.0,
                    help="per-round probability a scheduled client dies "
                         "mid-round (timeline frozen at the crash instant)")
    ap.add_argument("--pipeline", action="store_true",
                    help="overlap client compute with uplink streaming at "
                         "minibatch granularity (repro.wireless.timeline); "
                         "the deadline/energy gates and the accounting "
                         "price the overlapped timeline.  Staleness-"
                         "weighted async aggregation (staleness_lambda) is "
                         "a FedSim-side fold and is not exposed here — this "
                         "driver prices the scheduler side only")
    # ---- compression (repro.compress) ----
    ap.add_argument("--codec", default="fp32",
                    choices=["fp32", "int8", "int4", "topk", "fp8"],
                    help="codec for the split-learning wire payloads "
                         "(activations up, gradients down, offloads); this "
                         "driver prices it in the wireless accounting — the "
                         "CNN simulator (benchmarks/compress_sweep.py) "
                         "additionally applies it in the dataflow")
    ap.add_argument("--codec-bits", type=int, default=None,
                    help="override the uniform quantizer's bit width")
    ap.add_argument("--topk-frac", type=float, default=0.05,
                    help="kept fraction for --codec topk")
    # ---- observability (repro.telemetry) ----
    ap.add_argument("--trace-dir", default=None,
                    help="write telemetry into this directory: a streamed "
                         "Chrome/Perfetto trace of every wireless round "
                         "(trace.json — open at https://ui.perfetto.dev), "
                         "typed metrics snapshots (metrics.jsonl), a run "
                         "manifest (manifest.json), and a run-end summary "
                         "table (summary.txt).  Default: telemetry off, "
                         "bit-identical to a run without it")
    ap.add_argument("--metrics-every", type=int, default=1,
                    help="flush a metrics.jsonl snapshot every N rounds "
                         "(with --trace-dir)")
    args = ap.parse_args(argv)

    tel = (Telemetry(args.trace_dir, metrics_every=args.metrics_every,
                     kernels=True)
           if args.trace_dir else Telemetry.disabled())
    log = MetricLogger("train", telemetry=tel)
    cfg = get_arch(args.arch).reduced()
    model = build_model(cfg)
    C = args.clients
    population = None
    if args.population:
        if args.channel == "ideal":
            ap.error("--population requires a non-ideal --channel (the "
                     "cohort sampler lives on the wireless scheduler)")
        from repro.wireless.population import Population
        C = args.cohort_size or C
        if args.population < C:
            ap.error("--population must be >= the cohort size")
        population = Population(args.population, seed=args.seed)

    # single-host mesh: all clients on the 'data' axis of a (C,1) mesh if we
    # have C devices, else a (1,1) mesh with client dim = C still carried in
    # the arrays (shard_map over size-1 axes; aggregation becomes a segment
    # mean in the host round below).
    ndev = jax.device_count()
    if ndev >= C:
        mesh = jax.make_mesh((C, 1), ("data", "model"))
    else:
        mesh = jax.make_mesh((1, 1), ("data", "model"))

    hcfg = HierarchyConfig(num_edge_servers=1, clients_per_es=C,
                           kappa0=args.local_steps, kappa1=1,
                           global_rounds=args.rounds)
    tcfg = TrainConfig(learning_rate=args.lr, freeze_head=not args.hsfl,
                       local_steps_in_step=args.local_steps, remat=False,
                       finetune_steps=args.finetune_steps,
                       finetune_lr=args.lr)

    # wireless scenario: channel + participation scheduler (None = ideal)
    scheduler = None
    if args.channel != "ideal":
        from repro.compress import link_codecs
        codecs = None
        if args.codec != "fp32":
            codecs = link_codecs(args.codec, bits=args.codec_bits,
                                 topk_frac=args.topk_frac)
        candidates = tuple(args.cut_candidates or ())
        wcfg = WirelessConfig(model=args.channel,
                              mean_uplink_mbps=args.mean_rate_mbps,
                              mean_downlink_mbps=4 * args.mean_rate_mbps,
                              deadline_s=args.deadline,
                              energy_budget_j=args.energy_budget,
                              es_uplink_mbps=args.es_uplink_mbps,
                              cut_policy=args.cut_policy,
                              cut_candidates=candidates,
                              compute_gflops=args.compute_gflops,
                              compute_heterogeneity=args.compute_heterogeneity,
                              compute_power_w=args.compute_power_w,
                              codec_cycles_per_element=args.codec_cycles,
                              pipeline=args.pipeline,
                              faults=FaultConfig(
                                  erasure_prob=args.erasure_prob,
                                  max_retries=args.harq_retries,
                                  backoff_s=args.harq_backoff,
                                  crash_hazard=args.crash_hazard),
                              seed=args.seed)
        comm_kw = dict(seq_len=args.seq,
                       dataset_size=args.rounds * args.local_steps *
                       args.micro, batch_size=args.micro,
                       batches_per_epoch=1, codecs=codecs)
        if population is not None:
            from repro.wireless.population import CohortScheduler
            sched_u = population.N
            es_assign = population.es_assign
            sched_extra = dict(cls=CohortScheduler, population=population,
                               cohort_size=C, sampling=args.sampling)
        else:
            sched_u = C
            es_assign = es_assignment(C, hcfg.clients_per_es)
            sched_extra = {}
        if wcfg.cut_policy != "fixed" or candidates:
            table = comm_table_for_lm(
                cfg, cuts=candidates or (cfg.n_client_layers,), **comm_kw)
            if wcfg.cut_policy == "fixed" and cfg.n_client_layers not in table:
                raise ValueError(
                    f"--cut-policy fixed would price one of {tuple(table)} "
                    f"but the model's client depth is {cfg.n_client_layers}; "
                    f"include it in --cut-candidates")
            scheduler = make_scheduler(
                wcfg, sched_u, kappa0=hcfg.kappa0, comm_table=table,
                es_assign=es_assign,
                fixed_cut=cfg.n_client_layers
                if cfg.n_client_layers in table else 0,
                telemetry=tel, **sched_extra)
        else:
            comm = comm_for_lm(cfg, **comm_kw)
            scheduler = make_scheduler(wcfg, sched_u, comm, hcfg.kappa0,
                                       es_assign=es_assign, telemetry=tel,
                                       **sched_extra)
    participation = scheduler is not None
    tel.write_manifest(config=vars(args),
                       seeds={"seed": args.seed},
                       extra={"arch": args.arch, "clients": C})

    with set_mesh(mesh):
        if mesh.shape["data"] == C:
            round_ = make_phsfl_round(model, hcfg, tcfg, mesh,
                                      global_sync=False,
                                      participation=participation,
                                      cut=cfg.n_client_layers)
        else:
            # degenerate 1-device path: the mesh-free mirror of
            # make_phsfl_round (same local scan, same weighted aggregation
            # in agg_dtype, same per-client optimizer states)
            round_ = make_host_round(model, hcfg, tcfg, num_clients=C,
                                     global_sync=False,
                                     participation=participation,
                                     cut=cfg.n_client_layers)
        round_fn = jax.jit(round_.fn)

        params = init_stacked_params(model, jax.random.PRNGKey(args.seed),
                                     C)
        opt, _ = build_optimizer(model, tcfg)
        state1 = opt.init(jax.tree.map(lambda x: x[0], params))
        opt_state = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (C,) + x.shape),
            state1)
        au = jnp.full((C,), 1.0 / C, jnp.float32)
        ab = jnp.ones((C,), jnp.float32)

        # ---- full-state checkpointing (kill + --resume = bit-identical):
        # the state tree carries params, optimizer state, the round cursor,
        # the simulated clock, and the scheduler's mutable state (energy
        # budgets, stale bank, channel/thinning/fault RNG streams).  Per-
        # round batches are seeded ``args.seed + r``, so nothing else is
        # needed to replay the uninterrupted trajectory.
        sim_time = 0.0
        start_round = 0
        state_dir = (os.path.join(args.ckpt_dir, "state")
                     if args.ckpt_dir else None)

        def run_state(r):
            st = {"params": params, "opt_state": opt_state,
                  "round": np.int64(r), "sim_time_s": np.float64(sim_time)}
            if scheduler is not None:
                st["scheduler"] = scheduler.state_dict()
            return st

        if args.resume and state_dir:
            step = latest_step(state_dir)
            if step is not None:
                st = load_checkpoint(state_dir, step, run_state(0))
                params = jax.tree.map(jnp.asarray, st["params"])
                opt_state = jax.tree.map(jnp.asarray, st["opt_state"])
                start_round = int(st["round"])
                sim_time = float(st["sim_time_s"])
                if scheduler is not None:
                    scheduler.load_state_dict(st["scheduler"])
                log.log(resumed_from_round=float(start_round))

        t0 = time.time()
        metrics = {"loss": float("nan")}       # already-complete resume
        for r in range(start_round, args.rounds):
            batch = _client_round_batch(cfg, C, args.local_steps, args.micro,
                                        args.seq, seed=args.seed + r)
            if scheduler is not None:
                rep = scheduler.step(r)
                if population is not None:
                    # (N,)-wide report -> this round's C training slots
                    from repro.wireless.population import cohort_report
                    rep = cohort_report(rep, scheduler.last_cohort)
                sim_time += rep.round_time_s
                mask = jnp.asarray(rep.mask, jnp.float32)
                params, opt_state, metrics = round_fn(
                    params, opt_state, batch, au, ab, mask)
                extra = {}
                if rep.mean_cut is not None:
                    extra["mean_cut"] = rep.mean_cut
                if rep.compute_s is not None and rep.compute_s.any():
                    extra["compute_s_max"] = float(rep.compute_s.max())
                log.log(step=r, loss=metrics["loss"],
                        participants=rep.num_participants,
                        round_time_s=rep.round_time_s,
                        sim_time_s=sim_time, bits_tx=rep.bits_tx,
                        s_per_round=(time.time() - t0) / (r + 1), **extra)
            else:
                params, opt_state, metrics = round_fn(params, opt_state,
                                                      batch, au, ab)
                log.log(step=r, loss=metrics["loss"],
                        s_per_round=(time.time() - t0) / (r + 1))
            if (state_dir and args.ckpt_every > 0
                    and (r + 1) % args.ckpt_every == 0):
                save_checkpoint(state_dir, r + 1, run_state(r + 1))
            if args.abort_after is not None and r + 1 >= args.abort_after:
                # simulated crash for the resume smoke test: die right
                # after this round's checkpoint, skipping the final save
                tel.close()
                print(json.dumps({"aborted_after_round": r + 1}))
                return

        # ---- personalization (Eq. 18) ----
        global_params = jax.tree.map(lambda x: x[0], params)
        ft = _client_round_batch(cfg, C, 1, args.micro, args.seq, seed=777)
        ft = {k: v[:, 0] for k, v in ft.items()}       # (C, micro, ...)
        heads, ft_losses = personalize_head_bank(model, global_params, ft,
                                                 tcfg)
        ev_pers = personalized_eval(model, global_params, heads, ft)
        base_head = jnp.broadcast_to(global_params["lm_head"]["w"][None],
                                     heads.shape)
        ev_glob = personalized_eval(model, global_params, base_head, ft)
        for c in range(C):
            log.log(client=c, global_loss=ev_glob[c],
                    personalized_loss=ev_pers[c])
        gain = float((ev_glob - ev_pers).mean())
        log.log(personalization_gain=gain)

        if args.ckpt_dir:
            save_checkpoint(args.ckpt_dir, args.rounds, global_params)
            log.log(ckpt=1.0)

    tel.close()
    out = {"final_loss": float(metrics["loss"]),
           "personalization_gain": gain}
    if scheduler is not None:
        out["sim_time_s"] = sim_time
        out["energy_left_j_min"] = float(scheduler.energy_left.min())
    print(json.dumps(out))


if __name__ == "__main__":
    main()
