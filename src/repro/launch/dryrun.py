import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           # XLA *CPU* crashes cloning bf16 all-reduces in the
                           # AllReducePromotion pass (hlo_instruction.cc:1558,
                           # "Invalid binary instruction opcode copy"); the
                           # pass is a CPU-only numerics shim and we only
                           # lower+compile here, never execute.  Irrelevant on
                           # real TPU backends.
                           "--xla_disable_hlo_passes=all-reduce-promotion")

"""Multi-pod dry-run: .lower().compile() every (architecture x input shape)
on the production meshes, record memory/cost/collective analysis.

    PYTHONPATH=src python -m repro.launch.dryrun                 # everything
    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-12b \
        --shape train_4k --mesh single --train-mode shared_server

Results land in experiments/dryrun/<arch>__<shape>__<mesh>[__mode].json and
are aggregated by benchmarks/roofline_table.py into EXPERIMENTS.md §Roofline.

NOTE: the XLA_FLAGS line above MUST run before any other import (jax locks
the device count at first init); that is why it is the first statement.
"""

import argparse
import json
import sys
import time
import traceback

import jax

from repro.configs.registry import ARCHS, get_arch, supports_shape
from repro.configs.shapes import SHAPES
from repro.launch import roofline as rf
from repro.launch.mesh import make_production_mesh, num_chips, set_mesh
from repro.launch.steps import build_step

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def run_one(arch: str, shape_name: str, mesh_name: str, *,
            train_mode: str = "paper_faithful",
            serve_param_mode: str = "fsdp_tp", agg_dtype: str = "float32",
            remat: bool = True, remat_policy: str = "full",
            local_steps: int | None = None,
            out_dir: str = OUT_DIR, verbose: bool = True) -> dict:
    from repro.configs.base import TrainConfig

    cfg = get_arch(arch)
    shape = SHAPES[shape_name]
    if mesh_name == "alt32x8":
        from repro.launch.mesh import make_alt_mesh
        mesh = make_alt_mesh()
    else:
        mesh = make_production_mesh(multi_pod=(mesh_name == "multipod"))
    chips = num_chips(mesh)
    tcfg = None
    if (agg_dtype != "float32" or not remat or local_steps is not None
            or remat_policy != "full"):
        tcfg = TrainConfig(agg_dtype=agg_dtype, remat=remat,
                           remat_policy=remat_policy,
                           local_steps_in_step=local_steps or 2)
    t0 = time.time()
    with set_mesh(mesh):
        bundle = build_step(cfg, shape, mesh, train_mode=train_mode,
                            serve_param_mode=serve_param_mode, tcfg=tcfg)
        lowered = jax.jit(bundle.fn).lower(*bundle.args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        mem_report = ""
        try:
            mem_report = str(compiled.memory_analysis())
        except Exception as e:  # CPU backend may not support it fully
            mem_report = f"<memory_analysis unavailable: {e}>"

        roof = rf.analyze(compiled, None, arch=arch, shape=shape,
                          mesh_name=mesh_name, chips=chips, kind=shape.kind,
                          cfg=cfg, mesh_shape=dict(mesh.shape),
                          mode=train_mode, param_mode=serve_param_mode,
                          agg_dtype_bytes=(2 if agg_dtype == "bfloat16"
                                           else 4), tcfg=tcfg)

    rec = roof.to_dict()
    rec.update({"train_mode": train_mode if shape.kind == "train" else None,
                "step_meta": bundle.meta, "lower_s": round(t_lower, 1),
                "compile_s": round(t_compile, 1),
                "memory_analysis": mem_report})
    os.makedirs(out_dir, exist_ok=True)
    suffix = f"__{train_mode}" if (shape.kind == "train"
                                   and train_mode != "paper_faithful") else ""
    if shape.kind in ("decode", "prefill") and serve_param_mode != "fsdp_tp":
        suffix += f"__{serve_param_mode}"
    if shape.kind == "train" and agg_dtype != "float32":
        suffix += f"__agg{agg_dtype}"
    if shape.kind == "train" and not remat:
        suffix += "__noremat"
    if shape.kind == "train" and remat_policy != "full":
        suffix += f"__remat_{remat_policy}"
    if shape.kind == "train" and local_steps is not None:
        suffix += f"__k{local_steps}"
    fname = os.path.join(out_dir, f"{arch}__{shape_name}__{mesh_name}{suffix}.json")
    with open(fname, "w") as f:
        json.dump(rec, f, indent=1)
    if verbose:
        print(f"[dryrun] {arch:24s} {shape_name:12s} {mesh_name:8s} "
              f"ok chips={chips} "
              f"compute={roof.compute_s:.3e}s memory={roof.memory_s:.3e}s "
              f"collective={roof.collective_s:.3e}s dominant={roof.dominant} "
              f"(lower {t_lower:.0f}s compile {t_compile:.0f}s)", flush=True)
        print(f"  memory_analysis: {mem_report[:300]}", flush=True)
        print(f"  analytic: flops/chip={roof.flops:.3e} bytes/chip="
              f"{roof.hbm_bytes:.3e} coll_bytes/chip={roof.coll_bytes:.3e} "
              f"useful_flops_ratio={roof.useful_flops_ratio:.3f}", flush=True)
        print(f"  hlo(loop-bodies-once): flops={roof.hlo_flops:.3e} "
              f"bytes={roof.hlo_bytes:.3e} coll={roof.hlo_coll_bytes:.3e} "
              f"counts={roof.coll_detail.get('counts')}", flush=True)
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="architecture id (default all)")
    ap.add_argument("--shape", default=None, help="input shape (default all)")
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multipod", "both", "alt32x8"])
    ap.add_argument("--train-mode", default="paper_faithful",
                    choices=["paper_faithful", "shared_server"])
    ap.add_argument("--serve-params", default="fsdp_tp",
                    choices=["fsdp_tp", "tp"],
                    help="decode weight residency: fsdp (all-gather/step) "
                         "or tp-resident")
    ap.add_argument("--agg-dtype", default="float32",
                    choices=["float32", "bfloat16"],
                    help="hierarchical aggregation psum dtype")
    ap.add_argument("--no-remat", action="store_true",
                    help="disable per-block activation checkpointing")
    ap.add_argument("--remat-policy", default="full",
                    choices=["full", "dots"],
                    help="checkpoint policy: full recompute vs save-dots")
    ap.add_argument("--local-steps", type=int, default=None,
                    help="kappa0 local steps fused per round call")
    ap.add_argument("--out-dir", default=OUT_DIR)
    ap.add_argument("--keep-going", action="store_true",
                    help="continue past failures (collect all errors)")
    args = ap.parse_args(argv)

    archs = [args.arch] if args.arch else list(ARCHS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = ["single", "multipod"] if args.mesh == "both" else [args.mesh]

    failures = []
    n_ok = n_skip = 0
    for mesh_name in meshes:
        for arch in archs:
            for shape_name in shapes:
                if not supports_shape(arch, shape_name):
                    print(f"[dryrun] {arch:24s} {shape_name:12s} {mesh_name:8s} "
                          f"SKIP (long-context requires sub-quadratic mixing; "
                          f"see DESIGN.md)", flush=True)
                    n_skip += 1
                    continue
                try:
                    run_one(arch, shape_name, mesh_name,
                            train_mode=args.train_mode,
                            serve_param_mode=args.serve_params,
                            agg_dtype=args.agg_dtype,
                            remat=not args.no_remat,
                            remat_policy=args.remat_policy,
                            local_steps=args.local_steps,
                            out_dir=args.out_dir)
                    n_ok += 1
                except Exception as e:
                    failures.append((arch, shape_name, mesh_name, repr(e)))
                    print(f"[dryrun] {arch} {shape_name} {mesh_name} FAILED: {e}",
                          flush=True)
                    if not args.keep_going:
                        traceback.print_exc()
                        sys.exit(1)
    print(f"[dryrun] done: {n_ok} ok, {n_skip} skipped, {len(failures)} failed",
          flush=True)
    if failures:
        for f in failures:
            print("  FAIL:", *f)
        sys.exit(1)


if __name__ == "__main__":
    main()
