"""Roofline-term extraction from compiled dry-run artifacts.

    compute term    = HLO_FLOPs / (chips x 197 TF/s bf16)
    memory term     = HLO_bytes / (chips x 819 GB/s HBM)
    collective term = collective_bytes / (chips x 50 GB/s ICI link)

HLO_FLOPs / HLO_bytes come from compiled.cost_analysis().  XLA reports these
for the *partitioned per-device* module; we therefore treat them as
per-chip quantities and divide by single-chip peaks (equivalently: global
quantities over chips x peak).  collective_bytes is not in cost_analysis —
we parse the optimized HLO text and sum the output-shape bytes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
(output size is the standard per-device wire proxy; ring-algorithm factors
of 2(n-1)/n are O(1) and noted, not modeled).
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

# v5e-class hardware constants (per chip)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s/link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  bf16[8,128,2048]{2,1,0}  or  (f32[128], f32[128])
_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\(?[^=]*?\)?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.M)


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum output bytes per collective kind from optimized HLO text."""
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    seen_started = set()
    for m in _OP_RE.finditer(hlo_text):
        shape_str, kind = m.group(1), m.group(2)
        # avoid double counting start/done pairs: the -done line usually has
        # the same output shape; count "-start" once and plain ops once.
        line = hlo_text[m.start():hlo_text.find("\n", m.start())]
        if f"{kind}-done" in line:
            continue
        b = _shape_bytes(shape_str)
        out[kind] += b
        counts[kind] += 1
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    out["counts"] = counts
    return out


@dataclass
class Roofline:
    """Roofline terms for one (arch, shape, mesh) combination.

    The primary terms (compute_s / memory_s / collective_s) come from the
    ANALYTIC model (launch/analytic.py) because XLA's HloCostAnalysis counts
    while-loop bodies once, not x trip count, so compiled cost_analysis()
    undercounts our scan-heavy steps.  The raw HLO numbers are kept as
    hlo_* fields: they bound per-iteration cost and verify the collective
    schedule actually lowered (counts per collective kind).
    """
    arch: str
    shape: str
    mesh: str
    chips: int
    # analytic (per chip)
    flops: float
    hbm_bytes: float
    coll_bytes: float
    # raw compiled-HLO numbers (per device; loop bodies counted once)
    hlo_flops: float = 0.0
    hlo_bytes: float = 0.0
    hlo_coll_bytes: float = 0.0
    coll_detail: dict = field(default_factory=dict)
    analytic_detail: dict = field(default_factory=dict)
    model_flops: float = 0.0     # 6*N_active*D (global)
    peak_memory_bytes: float = 0.0

    @property
    def compute_s(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.coll_bytes / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / (global analytic flops): how much of the compute is
        'useful' (catches remat/redundancy/frontend waste)."""
        total = self.flops * self.chips
        return self.model_flops / total if total else 0.0

    def to_dict(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "flops_per_chip": self.flops,
            "hbm_bytes_per_chip": self.hbm_bytes,
            "collective_bytes_per_chip": self.coll_bytes,
            "hlo_flops_per_chip": self.hlo_flops,
            "hlo_bytes_per_chip": self.hlo_bytes,
            "hlo_collective_bytes_per_chip": self.hlo_coll_bytes,
            "collective_detail": self.coll_detail,
            "analytic_detail": self.analytic_detail,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "model_flops": self.model_flops,
            "useful_flops_ratio": self.useful_flops_ratio,
            "peak_memory_bytes": self.peak_memory_bytes,
        }


def active_params(cfg) -> int:
    """Parameter count; for MoE, the *active* (top-k) parameter count."""
    import jax

    from repro.models import build_model

    model = build_model(cfg)
    shapes = jax.eval_shape(lambda k: model.init(k), jax.random.PRNGKey(0))

    def leaf_count(path, s):
        import numpy as np
        n = int(np.prod(s.shape))
        if cfg.moe is not None and ("w_gate" in path or "w_up" in path
                                    or "w_down" in path):
            n = n * cfg.moe.top_k // cfg.moe.num_experts
        return n

    from repro.utils.tree import map_with_path
    counts = []
    map_with_path(lambda p, s: counts.append(leaf_count(p, s)) or s, shapes)
    return sum(counts)


def model_flops_for(cfg, shape, kind: str) -> float:
    """6*N*D train / 2*N*D inference, D = tokens processed per step."""
    n = active_params(cfg)
    if kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch


def analyze(compiled, lowered_text: str | None, *, arch: str, shape, mesh_name: str,
            chips: int, kind: str, cfg, mesh_shape: dict | None = None,
            mode: str = "paper_faithful", attn_impl: str = "masked",
            param_mode: str = "fsdp_tp", agg_dtype_bytes: int = 4,
            tcfg=None) -> Roofline:
    from repro.launch.analytic import cost_for

    cost = compiled.cost_analysis()
    if isinstance(cost, list):      # older API returned [dict]
        cost = cost[0] if cost else {}
    hlo_flops = float(cost.get("flops", 0.0))
    hlo_bytes = float(cost.get("bytes accessed", 0.0))
    text = compiled.as_text() if lowered_text is None else lowered_text
    coll = collective_bytes(text)
    peak = 0.0
    try:
        ma = compiled.memory_analysis()
        peak = float(getattr(ma, "temp_size_in_bytes", 0) or 0) + \
            float(getattr(ma, "argument_size_in_bytes", 0) or 0) + \
            float(getattr(ma, "output_size_in_bytes", 0) or 0)
    except Exception:
        pass
    ac = cost_for(cfg, shape, mesh_shape or {}, mode=mode,
                  attn_impl=attn_impl, param_mode=param_mode,
                  agg_dtype_bytes=agg_dtype_bytes, tcfg=tcfg)
    return Roofline(arch=arch, shape=shape.name, mesh=mesh_name, chips=chips,
                    flops=ac.flops, hbm_bytes=ac.hbm_bytes,
                    coll_bytes=ac.coll_bytes,
                    hlo_flops=hlo_flops, hlo_bytes=hlo_bytes,
                    hlo_coll_bytes=float(coll["total"]), coll_detail=coll,
                    analytic_detail=ac.detail,
                    model_flops=model_flops_for(cfg, shape, kind),
                    peak_memory_bytes=peak)
