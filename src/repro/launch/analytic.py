"""Analytic per-(arch x shape x mesh) cost model for the roofline terms.

WHY THIS EXISTS: XLA's HloCostAnalysis counts a while-loop body ONCE, not
times its trip count (verified experimentally — scan vs unroll differ by
exactly the trip count).  Our steps are scan-heavy (layer stacks, local SGD
steps, attention/loss chunks), so compiled.cost_analysis() undercounts by
the product of trip counts.  The roofline table therefore uses this analytic
model — the same napkin math §Perf hypotheses are made of — and records the
raw HLO numbers alongside for cross-checking (they bound the *per-iteration*
cost and verify the collective schedule).

All quantities are PER CHIP unless suffixed _global.
Conventions: multiply-add = 2 FLOPs; bf16 = 2 bytes; f32 = 4 bytes.
"""

from __future__ import annotations

from dataclasses import dataclass

from repro.configs.base import (ATTN, LOCAL_ATTN, MLA_ATTN, MLSTM, RGLRU,
                                SLSTM, ModelConfig, ShapeConfig, TrainConfig)

BF16 = 2
F32 = 4


# --------------------------------------------------------- per-layer flops --
def _attn_flops_per_token(cfg: ModelConfig, kv_len: float, *, causal_half: bool
                          ) -> float:
    """Projection + mixing FLOPs for one token through one attention layer."""
    d, qd, kvd, h, hd = (cfg.d_model, cfg.q_dim, cfg.kv_dim, cfg.num_heads,
                         cfg.head_dim)
    proj = 2 * d * (qd + 2 * kvd) + 2 * qd * d
    eff = kv_len / 2 if causal_half else kv_len
    mixing = 2 * 2 * h * hd * eff                      # qk^T and att@v
    return proj + mixing


def _mla_flops_per_token(cfg: ModelConfig, kv_len: float, *, causal_half: bool
                         ) -> float:
    m = cfg.mla
    d, h = cfg.d_model, cfg.num_heads
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    proj = 2 * d * m.q_lora_rank + 2 * m.q_lora_rank * h * qk \
        + 2 * d * (m.kv_lora_rank + m.qk_rope_head_dim) \
        + 2 * m.kv_lora_rank * h * (m.qk_nope_head_dim + m.v_head_dim) \
        + 2 * h * m.v_head_dim * d
    eff = kv_len / 2 if causal_half else kv_len
    mixing = 2 * h * (qk + m.v_head_dim) * eff
    return proj + mixing


def _ffn_flops_per_token(cfg: ModelConfig, layer_is_moe: bool, dense_ff: int
                         ) -> float:
    d = cfg.d_model
    if layer_is_moe:
        moe = cfg.moe
        f = 2 * d * moe.num_experts                    # router
        f += moe.top_k * 3 * 2 * d * moe.d_ff_expert
        if moe.num_shared_experts:
            f += 3 * 2 * d * moe.d_ff_shared * moe.num_shared_experts
        return f
    return 3 * 2 * d * dense_ff if dense_ff else 0.0


def _recurrent_flops_per_token(cfg: ModelConfig, kind: str) -> float:
    d = cfg.d_model
    if kind == RGLRU:
        w = cfg.rglru.lru_width or d
        return (2 * d * w * 2          # in_x, in_gate
                + 2 * w * w * 2        # w_a, w_x
                + 2 * cfg.rglru.conv_kernel * w
                + 8 * w                # gate math + recurrence
                + 2 * w * d)           # out
    xl = cfg.xlstm
    if kind == MLSTM:
        di = int(d * xl.proj_factor_mlstm)
        dh = di // xl.num_heads
        chunk = 256
        mixing = xl.num_heads * (2 * 2 * chunk * dh / 2      # intra (causal)
                                 + 2 * 2 * dh * dh / chunk)  # carry in/out
        return (2 * d * 2 * di + 3 * 2 * di * di
                + 2 * cfg.xlstm.conv_kernel * di + mixing + 2 * di * d)
    if kind == SLSTM:
        dh = d // xl.num_heads
        dff = int(d * xl.proj_factor_slstm)
        return (2 * d * 4 * d + xl.num_heads * 2 * dh * 4 * dh
                + 20 * d + 3 * 2 * d * dff)
    raise ValueError(kind)


def _layer_flops_per_token(cfg: ModelConfig, layer_id: int, kv_len: float, *,
                           causal_half: bool) -> float:
    kinds = cfg.layer_kinds()
    kind = kinds[layer_id]
    is_moe = cfg.moe is not None and layer_id >= (cfg.moe.first_dense_layers or 0)
    dense_ff = cfg.d_ff
    if cfg.moe is not None and not is_moe:
        dense_ff = cfg.moe.d_ff_dense
    if kind in (SLSTM, MLSTM):
        return _recurrent_flops_per_token(cfg, kind)
    if kind == RGLRU:
        return _recurrent_flops_per_token(cfg, kind) \
            + _ffn_flops_per_token(cfg, is_moe, dense_ff)
    if kind == MLA_ATTN:
        f = _mla_flops_per_token(cfg, kv_len, causal_half=causal_half)
    else:
        eff = min(kv_len, cfg.sliding_window) if kind == LOCAL_ATTN and \
            cfg.sliding_window else kv_len
        f = _attn_flops_per_token(cfg, eff,
                                  causal_half=causal_half and eff == kv_len)
    return f + _ffn_flops_per_token(cfg, is_moe, dense_ff)


def forward_flops_per_token(cfg: ModelConfig, kv_len: float, *,
                            causal_half: bool = False) -> float:
    """One token through the whole model (embeddings + layers + head)."""
    total = 2 * cfg.d_model * cfg.padded_vocab            # lm head
    for lid in range(cfg.num_layers):
        total += _layer_flops_per_token(cfg, lid, kv_len,
                                        causal_half=causal_half)
    if cfg.encdec is not None:
        # encoder layers over the source sequence, amortized per target token
        src = cfg.encdec.max_source_len
        enc = cfg.encdec.num_encoder_layers * (
            _attn_flops_per_token(cfg, src, causal_half=False)
            + _ffn_flops_per_token(cfg, False, cfg.d_ff))
        total += enc * src / max(kv_len, 1)
        # cross attention (already excluded from decoder loop approximations)
        total += cfg.num_layers * 2 * 2 * cfg.num_heads * cfg.head_dim * src
    return total


# ------------------------------------------------------------- whole step --
@dataclass
class AnalyticCost:
    flops: float            # per chip
    hbm_bytes: float        # per chip
    coll_bytes: float       # per chip
    detail: dict


def param_bytes_global(cfg: ModelConfig, dtype_bytes: int = BF16) -> float:
    from repro.launch.roofline import active_params  # full count
    import jax

    from repro.models import build_model
    from repro.utils.tree import tree_size

    model = build_model(cfg)
    shapes = jax.eval_shape(lambda k: model.init(k), jax.random.PRNGKey(0))
    return tree_size(shapes) * dtype_bytes


def train_cost(cfg: ModelConfig, shape: ShapeConfig, mesh_shape: dict, *,
               tcfg: TrainConfig | None = None,
               mode: str = "paper_faithful",
               attn_impl: str = "masked",
               agg_dtype_bytes: int = F32) -> AnalyticCost:
    """The PHSFL edge round: k_local fused steps + hierarchical aggregation.

    attn_impl: "masked" — the pure-JAX chunked path computes the full
    (S x S) rectangle and masks (baseline); "flash" — the Pallas kernel
    skips above-diagonal / out-of-window blocks (~2x mixing-FLOP saving for
    causal full attention).
    """
    tcfg = tcfg or TrainConfig()
    tp = mesh_shape.get("model", 1)
    clients = mesh_shape.get("data", 1) * mesh_shape.get("pod", 1)
    chips = tp * clients
    k = tcfg.local_steps_in_step
    micro = shape.global_batch // (clients * k)
    tokens_per_client = k * micro * shape.seq_len

    fwd = forward_flops_per_token(cfg, shape.seq_len,
                                  causal_half=(attn_impl == "flash"))
    # fwd + 2x bwd (+ recompute): full remat re-runs the whole forward
    # (+1.0); 'dots' policy saves matmul outputs and recomputes only the
    # cheap elementwise ops (~+0.3)
    if not tcfg.remat:
        mult = 3.0
    elif tcfg.remat_policy == "dots":
        mult = 3.3
    else:
        mult = 4.0
    flops_client = fwd * mult * tokens_per_client
    flops_chip = flops_client / tp

    pbytes = param_bytes_global(cfg)
    if mode == "paper_faithful":
        pbytes_chip = pbytes / tp              # one replica per client, TP'd
    else:
        pbytes_chip = pbytes / chips           # FSDP body (client block tiny)
    # traffic: read params fwd+bwd(+recompute), write update, grads rw;
    # activations: remat checkpoints written+read once per microbatch
    act_bytes = (cfg.num_layers * micro * shape.seq_len * cfg.d_model
                 * BF16 * 2) * k
    hbm = pbytes_chip * (mult + 2.0) * k + act_bytes

    # collectives per chip:
    # (1) TP all-reduces: ~4 per layer per microbatch of (micro,seq,d) bf16,
    #     ring factor 2(n-1)/n ~= 2
    coll_tp = 4 * cfg.num_layers * k * micro * shape.seq_len * cfg.d_model \
        * BF16 * 2 * (tp - 1) / max(tp, 1) if tp > 1 else 0.0
    # (2) edge aggregation: all-reduce of the trained params over 'data'
    nd = mesh_shape.get("data", 1)
    agg_bytes = pbytes_chip / BF16 * agg_dtype_bytes
    coll_edge = agg_bytes * 2 * (nd - 1) / nd if nd > 1 else 0.0
    if mode == "shared_server":
        # only the client block ships on the kappa0 boundary; body grads
        # all-reduce every step instead (approximately same magnitude as one
        # param all-reduce per step)
        coll_edge = coll_edge * 0.02 + agg_bytes * 2 * (nd - 1) / nd * k
    npod = mesh_shape.get("pod", 1)
    coll_pod = agg_bytes * 2 * (npod - 1) / npod if npod > 1 else 0.0
    coll = coll_tp + coll_edge + coll_pod

    return AnalyticCost(
        flops=flops_chip, hbm_bytes=hbm, coll_bytes=coll,
        detail={"tokens_per_client": tokens_per_client, "micro": micro,
                "param_bytes_per_chip": pbytes_chip,
                "coll_tp": coll_tp, "coll_edge": coll_edge,
                "coll_pod": coll_pod, "mode": mode})


def prefill_cost(cfg: ModelConfig, shape: ShapeConfig, mesh_shape: dict, *,
                 attn_impl: str = "masked",
                 param_mode: str = "fsdp_tp") -> AnalyticCost:
    tp = mesh_shape.get("model", 1)
    dp = mesh_shape.get("data", 1) * mesh_shape.get("pod", 1)
    chips = tp * dp
    batch_local = max(shape.global_batch // dp, 1)
    tokens_local = batch_local * shape.seq_len
    fwd = forward_flops_per_token(cfg, shape.seq_len,
                                  causal_half=(attn_impl == "flash"))
    flops_chip = fwd * tokens_local / tp
    pbytes_resident = param_bytes_global(cfg) / (chips if param_mode ==
                                                 "fsdp_tp" else tp)
    act = batch_local * shape.seq_len * cfg.d_model * BF16 * cfg.num_layers
    # fsdp all-gather of params (each chip gathers the other shards) + TP ARs
    coll_fsdp = (param_bytes_global(cfg) / chips) * (dp - 1) \
        if (dp > 1 and param_mode == "fsdp_tp") else 0.0
    coll_tp = 4 * cfg.num_layers * tokens_local * cfg.d_model * BF16 \
        * 2 * (tp - 1) / tp if tp > 1 else 0.0
    return AnalyticCost(
        flops=flops_chip,
        hbm_bytes=pbytes_resident + act,
        coll_bytes=coll_fsdp + coll_tp,
        detail={"batch_local": batch_local, "coll_fsdp": coll_fsdp,
                "coll_tp": coll_tp, "param_mode": param_mode})


def decode_cost(cfg: ModelConfig, shape: ShapeConfig, mesh_shape: dict, *,
                param_mode: str = "fsdp_tp") -> AnalyticCost:
    """One decode step with a seq_len-deep cache.

    param_mode: "fsdp_tp" — weights sharded over all axes, all-gathered per
    step (baseline serving layout); "tp" — weights TP-resident (replicated
    over the data axes), no per-step weight all-gather at dp x the weight
    memory.
    """
    tp = mesh_shape.get("model", 1)
    dp = mesh_shape.get("data", 1) * mesh_shape.get("pod", 1)
    chips = tp * dp
    batch_local = max(shape.global_batch // dp, 1)
    fwd = forward_flops_per_token(cfg, shape.seq_len, causal_half=False)
    flops_chip = fwd * batch_local / tp

    pbytes_resident = param_bytes_global(cfg) / (chips if param_mode ==
                                                 "fsdp_tp" else tp)
    cache_chip = _cache_bytes_global(cfg, shape) / chips
    hbm = pbytes_resident + cache_chip            # read weights + read cache
    coll_fsdp = (param_bytes_global(cfg) / chips) * (dp - 1) \
        if (dp > 1 and param_mode == "fsdp_tp") else 0.0
    coll_tp = 4 * cfg.num_layers * batch_local * cfg.d_model * BF16 \
        * 2 * (tp - 1) / tp if tp > 1 else 0.0
    return AnalyticCost(
        flops=flops_chip, hbm_bytes=hbm, coll_bytes=coll_fsdp + coll_tp,
        detail={"cache_bytes_per_chip": cache_chip,
                "param_bytes_resident_per_chip": pbytes_resident,
                "param_mode": param_mode, "coll_fsdp": coll_fsdp,
                "coll_tp": coll_tp})


def _cache_bytes_global(cfg: ModelConfig, shape: ShapeConfig) -> float:
    b, s = shape.global_batch, shape.seq_len
    total = 0.0
    for kind in cfg.layer_kinds():
        if kind == ATTN:
            total += b * s * cfg.kv_dim * 2 * BF16
        elif kind == LOCAL_ATTN:
            total += b * min(s, cfg.sliding_window) * cfg.kv_dim * 2 * BF16
        elif kind == MLA_ATTN:
            m = cfg.mla
            total += b * s * (m.kv_lora_rank + m.qk_rope_head_dim) * BF16
        elif kind == RGLRU:
            w = cfg.rglru.lru_width or cfg.d_model
            total += b * w * F32
        elif kind == MLSTM:
            di = int(cfg.d_model * cfg.xlstm.proj_factor_mlstm)
            dh = di // cfg.xlstm.num_heads
            total += b * cfg.xlstm.num_heads * (dh * dh + dh) * F32
        elif kind == SLSTM:
            total += b * cfg.d_model * 4 * F32
    if cfg.encdec is not None:
        total += b * cfg.encdec.max_source_len * cfg.kv_dim * 2 * BF16 \
            * cfg.num_layers
    return total


def cost_for(cfg: ModelConfig, shape: ShapeConfig, mesh_shape: dict, *,
             mode: str = "paper_faithful", attn_impl: str = "masked",
             param_mode: str = "fsdp_tp", agg_dtype_bytes: int = F32,
             tcfg: TrainConfig | None = None) -> AnalyticCost:
    if shape.kind == "train":
        return train_cost(cfg, shape, mesh_shape, mode=mode, tcfg=tcfg,
                          attn_impl=attn_impl, agg_dtype_bytes=agg_dtype_bytes)
    if shape.kind == "prefill":
        return prefill_cost(cfg, shape, mesh_shape, attn_impl=attn_impl,
                            param_mode=param_mode)
    return decode_cost(cfg, shape, mesh_shape, param_mode=param_mode)
