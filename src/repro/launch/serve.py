"""Personalized serving driver: batched decode with per-request heads.

Serves a reduced model with a *head bank*: each request carries a client
profile id; the trunk (client block + body, = w*) is shared across the
batch, and the final projection uses the request's own personalized
classifier w_{u,1,hd}^K (paper Sec. III-B).  This is the serving-side
contract of PHSFL — one shared trunk, many heads.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-12b \
        --batch 4 --steps 16
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig
from repro.configs.registry import get_arch
from repro.core import personalize_head_bank
from repro.data.synthetic import synthetic_token_batch
from repro.models import build_model
from repro.models.layers import softcap
from repro.telemetry import MetricLogger


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-12b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--steps", type=int, default=16)
    ap.add_argument("--clients", type=int, default=3)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    log = MetricLogger("serve")
    cfg = get_arch(args.arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))

    # ---- build a personalized head bank (Eq. 18) ----
    tcfg = TrainConfig(finetune_lr=0.2, finetune_steps=4)
    nbs = [synthetic_token_batch(c, 2, 32, cfg.vocab_size)
           for c in range(args.clients)]
    batches = {k: jnp.stack([jnp.asarray(nb[k]) for nb in nbs])
               for k in nbs[0]}
    if cfg.encdec is not None:
        batches["source_embeds"] = 0.02 * jnp.ones(
            (args.clients, 2, cfg.encdec.max_source_len, cfg.d_model),
            jnp.float32)
    head_bank, _ = personalize_head_bank(model, params, batches, tcfg)
    log.log(head_bank_clients=head_bank.shape[0])

    # ---- batched decode; per-request personalized final projection ----
    rng = np.random.default_rng(args.seed)
    profile_ids = jnp.asarray(rng.integers(0, args.clients, args.batch))
    heads = head_bank[profile_ids]                    # (B, D, V)
    max_len = args.prompt_len + args.steps
    cache = model.init_cache(args.batch, max_len, dtype=jnp.float32)
    if cfg.encdec is not None:
        from repro.models import encdec as ed
        src = 0.02 * jnp.ones((args.batch, cfg.encdec.max_source_len,
                               cfg.d_model), jnp.float32)
        memory = ed.encode(params, cfg, src)
        cache["cross"] = ed.precompute_cross(params, cfg, memory,
                                             dtype=jnp.float32)

    prompt = jnp.asarray(rng.integers(
        0, cfg.vocab_size, (args.batch, args.prompt_len)).astype(np.int32))

    @jax.jit
    def step(tok, cache, index, heads):
        hidden, cache = model.decode_step(params, tok, cache, index,
                                          return_hidden=True)
        lg = jnp.einsum("bqd,bdv->bqv", hidden.astype(jnp.float32),
                        heads.astype(jnp.float32))
        lg = softcap(lg, cfg.final_logit_softcap)
        return lg, cache

    t0 = time.time()
    for i in range(args.prompt_len - 1):              # prefill via stepping
        _, cache = step(prompt[:, i:i + 1], cache, jnp.asarray(i, jnp.int32),
                        heads)
    generated = []
    tok = prompt[:, -1:]
    for s in range(args.steps):
        idx = jnp.asarray(args.prompt_len - 1 + s, jnp.int32)
        logits, cache = step(tok, cache, idx, heads)
        tok = logits[:, :, :cfg.vocab_size].argmax(-1).astype(jnp.int32)
        generated.append(np.asarray(tok[:, 0]))
    wall = time.time() - t0
    toks = args.batch * (args.steps + args.prompt_len - 1)
    log.log(tokens=toks, tok_per_s=toks / wall, wall_s=wall)
    print(json.dumps({"generated": np.stack(generated, 1).tolist(),
                      "profiles": profile_ids.tolist(),
                      "tok_per_s": round(toks / wall, 1)}))


if __name__ == "__main__":
    main()
