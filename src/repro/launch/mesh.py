"""Production mesh construction.

Axis roles (DESIGN.md §2):
    pod    — PHSFL edge servers (CS-level aggregation domain), multi-pod only
    data   — clients within an edge server (edge-level aggregation domain)
    model  — tensor parallelism inside one client's model replica

Defined as functions (never module-level constants) so importing this module
never touches jax device state.
"""

from __future__ import annotations

import jax


def set_mesh(mesh):
    """Context manager activating ``mesh``, across jax versions.

    jax >= 0.5 exposes ``jax.set_mesh``; on 0.4.x a ``Mesh`` is itself the
    context manager.
    """
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_alt_mesh():
    """Same 256 chips, reshaped (32, 16->8 TP): the §Perf mesh-reshape
    iteration for TP-all-reduce-bound steps (halves per-chip TP activation
    traffic at the cost of more clients / FSDP shards)."""
    return jax.make_mesh((32, 8), ("data", "model"))


def make_debug_mesh(*, multi_pod: bool = False):
    """Small mesh for CPU integration tests (8 fake devices)."""
    shape = (2, 2, 2) if multi_pod else (4, 2)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def num_chips(mesh) -> int:
    n = 1
    for s in mesh.shape.values():
        n *= s
    return n


def num_clients(mesh) -> int:
    """Total client slots = product of the client-role axes."""
    n = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            n *= mesh.shape[a]
    return n
