"""Abstract input construction (ShapeDtypeStruct + NamedSharding) for every
(architecture x input-shape x mesh) dry-run combination.  No allocation.

Batch layout per step kind:

  train   (PHSFL round)   {"tokens","labels"}: (C, k_local, micro, seq)
                          C = pods*clients_per_pod client replicas,
                          k_local local SGD steps fused per round call,
                          micro = global_batch / C / k_local.
  prefill                 {"tokens","labels"}: (B, S) — batch over data axes.
  decode                  token (B,1) + per-layer KV/state cache.

Modality stubs ([vlm]/[audio]): patch/frame embeddings appear here as
precomputed inputs — exactly the allowed frontend carve-out.
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig, TrainConfig
from repro.launch.mesh import num_clients
from repro.models.registry import Model
from repro.sharding.rules import data_axes


def _dab(mesh: Mesh):
    ca = data_axes(mesh)
    return ca if len(ca) > 1 else ca[0]


def _dab_size(mesh: Mesh) -> int:
    n = 1
    for a in data_axes(mesh):
        n *= mesh.shape[a]
    return n


def _sds(shape, dtype, mesh, spec):
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec))


def _extras_specs(cfg: ModelConfig, lead_shape: tuple[int, ...], seq: int,
                  mesh: Mesh, lead_spec):
    """Modality-stub inputs with the given leading batch dims/spec."""
    extras = {}
    dt = jnp.dtype(cfg.dtype)
    if cfg.vlm is not None:
        extras["patch_embeds"] = _sds(
            lead_shape + (cfg.vlm.num_patch_tokens, cfg.d_model), dt, mesh,
            P(lead_spec))
        extras["positions3"] = _sds(lead_shape + (seq, 3), jnp.int32, mesh,
                                    P(lead_spec))
    if cfg.encdec is not None:
        extras["source_embeds"] = _sds(
            lead_shape + (cfg.encdec.max_source_len, cfg.d_model), dt, mesh,
            P(lead_spec))
    return extras


# ------------------------------------------------------------- train -------
def train_batch_specs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                      tcfg: TrainConfig):
    """Per-client-stacked batch for the paper-faithful PHSFL round."""
    C = num_clients(mesh)
    k = tcfg.local_steps_in_step
    micro = shape.global_batch // (C * k)
    assert micro >= 1, (shape.global_batch, C, k)
    lead = _dab(mesh)
    tok = _sds((C, k, micro, shape.seq_len), jnp.int32, mesh, P(lead))
    batch = {"tokens": tok, "labels": tok}
    batch.update(_extras_specs(cfg, (C, k, micro), shape.seq_len, mesh, lead))
    return batch


def train_weight_specs(mesh: Mesh):
    C = num_clients(mesh)
    lead = _dab(mesh)
    a = _sds((C,), jnp.float32, mesh, P(lead))
    return a, a


# ----------------------------------------------------- prefill / decode ----
def prefill_batch_specs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh):
    ds = _dab_size(mesh)
    lead = _dab(mesh) if shape.global_batch % ds == 0 else None
    tok = _sds((shape.global_batch, shape.seq_len), jnp.int32, mesh, P(lead))
    batch = {"tokens": tok, "labels": tok}
    batch.update(_extras_specs(cfg, (shape.global_batch,), shape.seq_len,
                               mesh, lead))
    return batch


def decode_token_specs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh):
    ds = _dab_size(mesh)
    lead = _dab(mesh) if shape.global_batch % ds == 0 else None
    tok = _sds((shape.global_batch, 1), jnp.int32, mesh, P(lead))
    extras = {}
    if cfg.vlm is not None:
        extras["positions3"] = _sds((shape.global_batch, 1, 3), jnp.int32,
                                    mesh, P(lead))
    return tok, extras


def cache_specs(model: Model, shape: ShapeConfig, mesh: Mesh,
                dtype=jnp.bfloat16):
    """Sharded abstract decode cache.

    Rules: shard the batch dim over the data axes when divisible; for
    global_batch=1 (long_500k) shard the cache *length* dim instead; shard
    very wide state dims (>=1024) over 'model'.
    """
    B = shape.global_batch
    S = shape.seq_len
    ds = _dab_size(mesh)
    dab = _dab(mesh)
    model_size = mesh.shape["model"]
    cache_shapes = jax.eval_shape(
        lambda: model.init_cache(B, S, dtype=dtype))

    # which top-level stages are scanned (leading repeats dim on leaves)?
    scanned_prefixes = set()
    if model.cfg.encdec is not None:
        scanned_prefixes.update({"self", "cross"})
    else:
        from repro.models.transformer import compute_stages
        for si, st in enumerate(compute_stages(model.cfg)):
            if st.which == "scan":
                scanned_prefixes.add(f"stage{si}")

    from repro.utils.tree import map_with_path

    def leaf_spec(path, leaf):
        top = path.split("/")[0]
        off = 1 if top in scanned_prefixes else 0
        entries = [None] * leaf.ndim
        shp = leaf.shape
        if B > 1 and B % ds == 0 and off < leaf.ndim and shp[off] == B:
            entries[off] = dab
        elif B == 1 and leaf.ndim > off + 1 and shp[off + 1] >= ds \
                and shp[off + 1] % ds == 0:
            entries[off + 1] = dab          # shard cache length (long_500k)
        # wide diagonal state dims over model axis
        if leaf.ndim >= off + 2 and shp[-1] >= 1024 \
                and shp[-1] % model_size == 0:
            entries[-1] = "model"
        # attention kv heads over model axis
        if leaf.ndim - off == 4 and shp[off + 2] % model_size == 0 \
                and shp[off + 2] > 1:
            entries[off + 2] = "model"
        return _sds(shp, leaf.dtype, mesh, P(*entries))

    return map_with_path(leaf_spec, cache_shapes)
